"""Tiled Program IR: the count-what-you-execute invariants.

The Program is the single lowered artifact: these tests pin (a) functional
equivalence of genuinely tiled execution (capacity-bound, n_tiles > 1 on
every rank) against the einsum oracle, and (b) byte-accounting identity
between ``Program.minisa_bits`` and ``isa.trace_bits`` of the flattened
instruction stream."""

import dataclasses

import numpy as np
import pytest

from repro import backends
from repro.configs.feather import feather_config
from repro.core import isa, machine, mapper, perf, program

RNG = np.random.default_rng(3)


def _tiny_cfg():
    """Buffers shrunk so a 20x12x18 GEMM tiles on every rank."""
    return dataclasses.replace(feather_config(4, 4), str_bytes=16 * 8,
                               sta_bytes=8 * 8, ob_bytes=16 * 8 * 4)


def _choice(df=isa.Dataflow.WOS):
    return mapper.MappingChoice(df=df, vn=4, m_t=8, k_t=8, n_t=8,
                                n_kg=1, n_nb=1, dup=4)


@pytest.mark.parametrize("df", [isa.Dataflow.WOS, isa.Dataflow.IOS])
def test_capacity_bound_tiling_matches_oracle(df):
    cfg = _tiny_cfg()
    g = mapper.Gemm(m=20, k=12, n=18)
    prog = program.lower(g, _choice(df), cfg)
    assert prog.n_m > 1 and prog.n_n > 1 and prog.n_k > 1
    assert prog.residency == {"stationary": "tiled", "streaming": "tiled"}
    i = RNG.standard_normal((g.m, g.k)).astype(np.float32)
    w = RNG.standard_normal((g.k, g.n)).astype(np.float32)
    out = machine.run_program(cfg, prog, {"I": i, "W": w})["O"]
    np.testing.assert_allclose(out, i @ w, rtol=2e-4, atol=2e-4)


def test_panel_residency_matches_oracle():
    """Stationary k-panel resident (incremental Loads reused over the m
    loop), streaming tiled."""
    cfg = dataclasses.replace(feather_config(4, 4), str_bytes=16 * 6,
                              sta_bytes=12 * 8, ob_bytes=16 * 8 * 4)
    g = mapper.Gemm(m=20, k=12, n=18)
    prog = program.lower(g, _choice(), cfg)
    assert prog.residency["stationary"] == "panel"
    i = RNG.standard_normal((g.m, g.k)).astype(np.float32)
    w = RNG.standard_normal((g.k, g.n)).astype(np.float32)
    out = machine.run_program(cfg, prog, {"I": i, "W": w})["O"]
    np.testing.assert_allclose(out, i @ w, rtol=2e-4, atol=2e-4)


def test_program_bytes_equal_flattened_trace_bits():
    """minisa_bits (computed from counts) == trace_bits of the materialised
    stream, for every residency mode."""
    cases = [
        (feather_config(4, 4), mapper.Gemm(m=12, k=16, n=12)),   # full
        (_tiny_cfg(), mapper.Gemm(m=20, k=12, n=18)),            # tiled
        (dataclasses.replace(feather_config(4, 4), str_bytes=16 * 6,
                             sta_bytes=12 * 8, ob_bytes=16 * 8 * 4),
         mapper.Gemm(m=20, k=12, n=18)),                         # panel
    ]
    for cfg, g in cases:
        prog = program.lower(g, _choice(), cfg)
        flat = isa.trace_bits(prog.instructions(), cfg)
        assert flat == prog.minisa_bits(), prog.residency


def test_tile_costs_conserve_loads_and_macs():
    """The perf tile stream is the Program's tiles: MACs, loads and stores
    sum to the workload's totals (reload factors appear as extra Load
    instructions, not as scaled formulas)."""
    cfg = _tiny_cfg()
    g = mapper.Gemm(m=20, k=12, n=18)
    prog = program.lower(g, _choice(), cfg)
    tiles = prog.tile_costs("minisa")
    assert len(tiles) == prog.n_tiles
    assert sum(t.macs for t in tiles) == g.macs
    assert sum(t.store_bytes for t in tiles) == g.m * g.n * cfg.elem_bytes
    # streaming operand is reloaded once per n-tile sweep (n-outer loop)
    load_total = sum(t.load_bytes for t in tiles)
    i_bytes, w_bytes = g.m * g.k, g.k * g.n
    assert load_total == i_bytes * prog.n_n + w_bytes * prog.n_m
    # and the loads equal the Load instructions' own length fields
    load_from_insts = sum(
        op.inst.length for op in prog.trace_ops()
        if isinstance(op.inst, isa.Load)) * cfg.elem_bytes
    assert load_from_insts == load_total


def test_perf_simulate_consumes_program_tiles():
    cfg = _tiny_cfg()
    g = mapper.Gemm(m=20, k=12, n=18)
    prog = program.lower(g, _choice(), cfg)
    res = perf.simulate(prog.tile_costs("minisa"), cfg)
    assert res.cycles >= prog.compute_cycles
    assert res.macs == g.macs


def test_elide_input_transform():
    """Chained-consumer transform drops exactly one SetIVNLayout + the
    input Load; only legal when the input operand is fully resident."""
    cfg = feather_config(4, 4)
    g = mapper.Gemm(m=10, k=12, n=8)
    prog = program.lower(g, _choice(), cfg)
    assert program.input_elidable(prog)
    elided = program.elide_input(prog)
    base = {k: v for k, v in prog.summary()["counts"].items()}
    after = {k: v for k, v in elided.summary()["counts"].items()}
    assert base["SetIVNLayout"] == after.get("SetIVNLayout", 0) + 1
    assert base["Load"] == after["Load"] + 1
    assert elided.minisa_bits() < prog.minisa_bits()
    # a capacity-bound input is NOT elidable (its loads are structural)
    tiled = program.lower(mapper.Gemm(m=20, k=12, n=18), _choice(),
                          _tiny_cfg())
    assert not program.input_elidable(tiled)
    assert program.elide_input(tiled) is tiled


@pytest.mark.parametrize("consumer_df", [isa.Dataflow.WOS, isa.Dataflow.IOS])
def test_chain_commit_matches_oracle(consumer_df):
    """program.chain wires producer commit -> consumer elision for both
    consumer dataflows (under IO-S the *stationary* operand is the input,
    so the elision must skip that load, not the streaming one)."""
    cfg = feather_config(4, 4)
    g1 = mapper.Gemm(m=10, k=12, n=8)
    g2 = mapper.Gemm(m=10, k=8, n=6)
    p1 = program.lower(g1, _choice(), cfg, out_name="O0")
    p2 = program.lower(g2, _choice(consumer_df), cfg, out_name="O1")
    chained = program.chain([p1, p2])
    assert chained[1].input_elided
    # consumer loads only its weight-side operand
    load_tensors = [op.meta["tensor"] for op in chained[1].trace_ops()
                    if isinstance(op.inst, isa.Load)]
    assert load_tensors == ["W"]
    i0 = RNG.standard_normal((10, 12)).astype(np.float32)
    w1 = RNG.standard_normal((12, 8)).astype(np.float32)
    w2 = RNG.standard_normal((8, 6)).astype(np.float32)
    m = backends.InterpreterBackend(cfg)
    m.run_program(chained[0], {"I": i0, "W": w1})
    m.run_program(chained[1], {"W": w2})
    np.testing.assert_allclose(m.outputs["O1"], (i0 @ w1) @ w2,
                               rtol=2e-4, atol=2e-4)


def test_chain_mixed_vn_retargets_and_commits():
    """A(vn=2) -> B(vn=4) -> C(vn=4): B cannot elide (vn mismatch with A)
    so its input Load is retargeted to A's committed output, and that
    rewiring must survive B's own commit-for-C re-lower.  The original
    Programs are not mutated."""
    cfg = feather_config(4, 4)
    gs = [mapper.Gemm(m=8, k=8, n=8), mapper.Gemm(m=8, k=8, n=8),
          mapper.Gemm(m=8, k=8, n=8)]
    ch2 = mapper.MappingChoice(df=isa.Dataflow.WOS, vn=2, m_t=8, k_t=8,
                               n_t=8, n_kg=1, n_nb=1, dup=4)
    progs = [program.lower(gs[0], ch2, cfg, out_name="O0"),
             program.lower(gs[1], _choice(), cfg, out_name="O1"),
             program.lower(gs[2], _choice(), cfg, out_name="O2")]
    chained = program.chain(progs)
    assert not chained[1].input_elided and chained[2].input_elided
    b_inputs = [op.meta["tensor"] for op in chained[1].trace_ops()
                if isinstance(op.inst, isa.Load)
                and op.meta["operand"] == "I"]
    assert b_inputs == ["O0"]
    # the caller's Program was not mutated by the retarget
    assert all(op.meta["tensor"] in ("I", "W")
               for op in progs[1].trace_ops()
               if isinstance(op.inst, isa.Load))
    i0 = RNG.standard_normal((8, 8)).astype(np.float32)
    ws = [RNG.standard_normal((8, 8)).astype(np.float32) for _ in range(3)]
    m = backends.InterpreterBackend(cfg)
    m.run_program(chained[0], {"I": i0, "W": ws[0]})
    m.run_program(chained[1], {"W": ws[1]})
    m.run_program(chained[2], {"W": ws[2]})
    np.testing.assert_allclose(m.outputs["O2"], ((i0 @ ws[0]) @ ws[1]) @ ws[2],
                               rtol=2e-4, atol=2e-4)


def test_row_wise_activation_rejected_on_tiled_output():
    """Partial-row drains cannot apply softmax/norms: loud error, not
    silently wrong numbers."""
    cfg = _tiny_cfg()
    g = mapper.Gemm(m=20, k=12, n=18)
    softmax = lambda x: np.exp(x) / np.exp(x).sum(-1, keepdims=True)
    with pytest.raises(ValueError, match="row-wise activation"):
        program.lower(g, _choice(), cfg, activation=softmax,
                      act_name="softmax")
    # elementwise activations stay legal on the same tiling
    prog = program.lower(g, _choice(), cfg,
                         activation=lambda x: np.maximum(x, 0),
                         act_name="relu")
    assert prog.n_n > 1


def test_searched_program_is_plan_artifact():
    """mapper.search returns the lowered Program and scores it with the
    same tile stream perf.simulate sees."""
    cfg = feather_config(8, 8)
    g = mapper.Gemm(m=96, k=40, n=88)
    plan = mapper.search(g, cfg)
    res = perf.simulate(plan.program.tile_costs("minisa"), cfg)
    assert res.cycles == pytest.approx(plan.perf_minisa.cycles)
    # summary byte counts come from the same Program
    s = plan.summary()
    assert s["instr_bytes_minisa"] == pytest.approx(
        plan.program.minisa_bytes())
