"""Execution-backend equivalence: PallasBackend == InterpreterBackend ==
einsum oracle, for every mapping the mapper emits across the Tab. IV
workload sweep (CI-scaled extents), plus the compiled-lowering invariants
(grid/BlockSpec derivation, IO-S out_block_t, activation fusion, chained
Programs)."""

import dataclasses

import numpy as np
import pytest

from repro import backends
from repro.configs.feather import feather_config
from repro.core import isa, mapper, program, workloads

RNG = np.random.default_rng(7)


def _tensors(g):
    return {
        "I": RNG.standard_normal((g.m, g.k)).astype(np.float32),
        "W": RNG.standard_normal((g.k, g.n)).astype(np.float32),
    }


def _choice(df=isa.Dataflow.WOS, vn=4):
    return mapper.MappingChoice(df=df, vn=vn, m_t=8, k_t=8, n_t=8,
                                n_kg=1, n_nb=1, dup=4)


# ---------------------------------------------------------------------------
# The correctness spine: the 50+-GEMM sweep on both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gemm", workloads.ci_suite(),
                         ids=lambda g: g.name)
def test_backend_equivalence_workload_sweep(gemm):
    """Search each Tab. IV workload (CI extents), lower the winning
    mapping once, and demand interpreter == pallas == oracle at fp32
    accumulate tolerance."""
    cfg = feather_config(4, 16)
    plan = mapper.search(gemm, cfg)
    backends.cross_check(plan.program, _tensors(gemm))


def test_ci_suite_covers_the_paper_sweep():
    suite = workloads.ci_suite()
    # the Tab. IV families plus the one conv (im2col) workload
    assert len(suite) == len(workloads.suite()) + 1
    # pairwise distinct: every entry is its own mapping-search problem
    assert len({(g.m, g.k, g.n) for g in suite}) == len(suite) >= 50
    assert max(max(g.m, g.k, g.n) for g in suite) <= 256
    domains = {g.name.split("-")[0] for g in suite}
    assert domains == {"fhe", "zkp", "gpt", "conv"}
    conv_gemm = workloads.ci_conv().to_gemm()
    assert any((g.m, g.k, g.n) == (conv_gemm.m, conv_gemm.k, conv_gemm.n)
               for g in suite)


# ---------------------------------------------------------------------------
# Forced lowerings: residency modes, dataflows, activations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("df", [isa.Dataflow.WOS, isa.Dataflow.IOS])
def test_backends_agree_on_capacity_bound_tiling(df):
    """Shrunk buffers force tiled residency on every rank; both backends
    must still agree with the oracle (the pallas grid covers n_m x n_n x
    n_k > 1 kernel blocks)."""
    cfg = dataclasses.replace(feather_config(4, 4), str_bytes=16 * 8,
                              sta_bytes=8 * 8, ob_bytes=16 * 8 * 4)
    g = mapper.Gemm(m=20, k=12, n=18)
    prog = program.lower(g, _choice(df), cfg)
    assert prog.residency == {"stationary": "tiled", "streaming": "tiled"}
    comp = backends.compile_program(prog)
    assert comp.n_launches > 1
    backends.cross_check(prog, _tensors(g))


@pytest.mark.parametrize("df", [isa.Dataflow.WOS, isa.Dataflow.IOS])
def test_pallas_lowering_geometry(df):
    """The compiled grid/blocks derive from the Program's snapped tiling
    (search orientation mapped to host coordinates) and the IO-S
    transposed accumulator lowers to the out_block_t index map."""
    cfg = feather_config(4, 4)
    g = mapper.Gemm(m=20, k=12, n=18)
    prog = program.lower(g, _choice(df), cfg)
    comp = backends.compile_program(prog)
    m_t, k_t, n_t = program.snap_tiling(g, prog.choice, cfg)
    wos = df == isa.Dataflow.WOS
    assert comp.out_block_t == (not wos)
    assert (comp.bm, comp.bk, comp.bn) == \
        ((m_t, k_t, n_t) if wos else (n_t, k_t, m_t))
    import math
    assert comp.grid == (math.ceil(g.m / comp.bm),
                         math.ceil(g.n / comp.bn),
                         math.ceil(g.k / comp.bk))


def test_pallas_fused_and_host_activations():
    """Elementwise act_name lowers to the in-kernel fusion; an unknown
    callable falls back to host application -- both must match the
    interpreter."""
    cfg = feather_config(4, 4)
    g = mapper.Gemm(m=10, k=12, n=8)
    t = _tensors(g)
    relu_prog = program.lower(g, _choice(), cfg,
                              activation=lambda x: np.maximum(x, 0),
                              act_name="relu")
    assert backends.compile_program(relu_prog).fused_act == "relu"
    backends.cross_check(relu_prog, t)
    square = lambda x: x * x
    sq_prog = program.lower(g, _choice(), cfg, activation=square,
                            act_name="none")
    comp = backends.compile_program(sq_prog)
    assert comp.fused_act is None and comp.host_act is square
    backends.cross_check(sq_prog, t)


# ---------------------------------------------------------------------------
# Chained Programs (paper §IV-G) across backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["interpreter", "pallas"])
def test_chain_commit_and_elision(backend):
    """Producer commits on-chip, consumer elides its input Load: both
    backends resolve the chain to the 2-layer oracle."""
    cfg = feather_config(4, 4)
    g1 = mapper.Gemm(m=10, k=12, n=8)
    g2 = mapper.Gemm(m=10, k=8, n=6)
    p1 = program.lower(g1, _choice(), cfg, out_name="O0")
    p2 = program.lower(g2, _choice(), cfg, out_name="O1")
    chained = program.chain([p1, p2])
    assert chained[1].input_elided
    i0 = RNG.standard_normal((10, 12)).astype(np.float32)
    w1 = RNG.standard_normal((12, 8)).astype(np.float32)
    w2 = RNG.standard_normal((8, 6)).astype(np.float32)
    be = backends.get_backend(backend, cfg)
    be.run_program(chained[0], {"I": i0, "W": w1})
    out = be.run_program(chained[1], {"W": w2})
    np.testing.assert_allclose(out["O1"], (i0 @ w1) @ w2,
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("backend", ["interpreter", "pallas"])
def test_chain_retargeted_input(backend):
    """vn-mismatched neighbour cannot elide: its input Load is retargeted
    to the producer's named output, which both backends resolve from
    their own outputs."""
    cfg = feather_config(4, 4)
    gs = [mapper.Gemm(m=8, k=8, n=8)] * 3
    progs = [program.lower(gs[0], _choice(vn=2), cfg, out_name="O0"),
             program.lower(gs[1], _choice(), cfg, out_name="O1"),
             program.lower(gs[2], _choice(), cfg, out_name="O2")]
    chained = program.chain(progs)
    assert not chained[1].input_elided and chained[2].input_elided
    i0 = RNG.standard_normal((8, 8)).astype(np.float32)
    ws = [RNG.standard_normal((8, 8)).astype(np.float32) for _ in range(3)]
    be = backends.get_backend(backend, cfg)
    be.run_program(chained[0], {"I": i0, "W": ws[0]})
    be.run_program(chained[1], {"W": ws[1]})
    out = be.run_program(chained[2], {"W": ws[2]})
    np.testing.assert_allclose(out["O2"], ((i0 @ ws[0]) @ ws[1]) @ ws[2],
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# API surface
# ---------------------------------------------------------------------------

def test_backend_registry_and_plan_execute():
    cfg = feather_config(4, 4)
    with pytest.raises(ValueError, match="unknown backend"):
        backends.get_backend("fpga", cfg)
    be = backends.PallasBackend(cfg)
    assert backends.get_backend(be, cfg) is be
    g = mapper.Gemm(m=10, k=12, n=8)
    plan = mapper.search(g, cfg)
    t = _tensors(g)
    oracle = t["I"] @ t["W"]
    for backend in ("interpreter", "pallas"):
        out = plan.execute(t, backend=backend)["O"]
        np.testing.assert_allclose(out, oracle, rtol=2e-4, atol=2e-4)


def test_interpreter_backend_is_machine_semantics():
    """module-level machine.run_program (the compat wrapper) and the
    InterpreterBackend produce identical arrays."""
    from repro.core import machine
    cfg = feather_config(4, 16)
    g = mapper.Gemm(m=17, k=40, n=24)
    prog = mapper.search(g, cfg).program
    t = _tensors(g)
    a = machine.run_program(cfg, prog, t)["O"]
    b = backends.InterpreterBackend(cfg).run_program(prog, t)["O"]
    np.testing.assert_array_equal(a, b)


def test_pallas_max_block_subdivision():
    """max_block bounds one kernel block's working set: the grid refines
    but the numbers do not change."""
    cfg = feather_config(8, 8)
    g = mapper.Gemm(m=96, k=64, n=96)
    prog = mapper.search(g, cfg).program
    t = _tensors(g)
    small = backends.PallasBackend(cfg, max_block=32)
    comp = small.compile(prog)
    assert max(comp.bm, comp.bk, comp.bn) <= 64  # full residency: <= 2x cap
    out = small.run_program(prog, t)["O"]
    np.testing.assert_allclose(out, t["I"] @ t["W"], rtol=2e-4, atol=2e-2)
