"""Observability spine: tracer semantics, the metrics registry, the
exporters, and the no-perturbation guarantee -- a traced serving run's
per-request ``state_checksum``s are bit-identical to an untraced one on
both backends, and the disabled-mode instrumentation overhead is bounded
against a measured decode tick."""

import json
import threading
import time

import pytest

from repro.configs.feather import feather_config
from repro.obs import export, metrics
from repro.obs.metrics import Registry
from repro.obs.trace import NULL_SPAN, Tracer, trace
from repro.runtime import ModelExecutable, ProgramCache, Scheduler

CFG = feather_config(4, 16)

#: mixed decode lengths + one chunked prompt: retire-mid-batch and
#: multi-tick prefill both appear in the trace
SUBMISSIONS = [(3, None), (1, None), (2, 64)]


@pytest.fixture(autouse=True)
def _clean_tracer():
    trace.disable()
    trace.clear()
    yield
    trace.disable()
    trace.clear()


def _serve(backend, **kw):
    cache = ProgramCache()
    prefill = ModelExecutable.for_cell("gemma-7b", "prefill_tiny", CFG,
                                       cache=cache)
    decode = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                      cache=cache)
    sched = Scheduler(prefill, decode, backend=backend,
                      max_concurrent=3, seed=0, **kw)
    for steps, prompt in SUBMISSIONS:
        sched.submit(decode_steps=steps, prompt_tokens=prompt)
    return sched.run()


def _checksums(rep):
    return [r.state_checksum for r in rep.requests]


@pytest.fixture(scope="module")
def traced_serving():
    """One traced batched-pallas serving run: (report, events, metrics
    snapshot) -- shared by the exporter/timeline/overhead tests."""
    metrics.reset()
    trace.clear()
    trace.enable()
    try:
        rep = _serve("pallas")
    finally:
        trace.disable()
    events = trace.events()
    snap = metrics.snapshot()
    trace.clear()
    return rep, events, snap


# ---------------------------------------------------------------------------
# Tracer semantics
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_null_singleton():
    t = Tracer()
    sp = t.span("x", a=1)
    assert sp is NULL_SPAN and not sp
    with sp as inner:
        inner.set(b=2)
    t.instant("marker")
    t.record("r", ("host", "x"), 0.0, 1.0)
    assert t.events() == []


def test_nesting_depth_and_track_inheritance():
    t = Tracer()
    t.enable()
    with t.span("outer"):
        with t.span("mid", ("request", 7)):
            with t.span("inner"):
                pass
    evs = {e.name: e for e in t.events()}
    assert evs["outer"].depth == 0
    assert evs["mid"].depth == 1
    assert evs["inner"].depth == 2
    # inner completes first (exit order), outer last
    assert [e.name for e in t.events()] == ["inner", "mid", "outer"]
    assert [e.seq for e in t.events()] == [0, 1, 2]
    # explicit track pins; children inherit the enclosing lane
    assert evs["outer"].track[0] == "host"
    assert evs["mid"].track == ("request", 7)
    assert evs["inner"].track == ("request", 7)
    # timing sanity: containment
    assert evs["outer"].t0_s <= evs["inner"].t0_s
    assert evs["inner"].t1_s <= evs["outer"].t1_s + 1e-9


def test_span_set_attrs_and_instants_and_record():
    t = Tracer()
    t.enable()
    with t.span("work", n=3) as sp:
        sp.set(launches=5)
    t.instant("mark", ("request", 0), rid=0)
    t.record("window", ("request", 0), 10.0, 10.5, step=1)
    work, mark, window = t.events()
    assert work.attrs == {"n": 3, "launches": 5}
    assert mark.instant and mark.dur_s == 0.0
    assert window.dur_s == pytest.approx(0.5)
    assert not window.instant


def test_threads_get_separate_lanes():
    t = Tracer()
    t.enable()

    def worker():
        with t.span("w"):
            pass

    th = threading.Thread(target=worker, name="side")
    with t.span("main_side"):
        th.start()
        th.join()
    tracks = {e.name: e.track for e in t.events()}
    assert tracks["w"] == ("host", "side")
    assert tracks["w"] != tracks["main_side"]


# ---------------------------------------------------------------------------
# Determinism: seeded scheduler -> identical span key sequences
# ---------------------------------------------------------------------------

def test_span_keys_deterministic_across_seeded_runs():
    """Two identically-seeded serving runs must emit the identical
    (name, track, depth) sequence -- the timing-free trace identity."""
    keys = []
    for _ in range(2):
        trace.clear()
        trace.enable()
        try:
            _serve("interpreter", batch_decode=False, use_fused=False)
        finally:
            trace.disable()
        keys.append(trace.keys())
    assert keys[0] == keys[1]
    assert len(keys[0]) > 0


# ---------------------------------------------------------------------------
# No perturbation: checksums identical tracing on vs off, both backends
# ---------------------------------------------------------------------------

def test_tracing_does_not_perturb_interpreter_serving():
    ref = _checksums(_serve("interpreter", batch_decode=False,
                            use_fused=False))
    trace.clear()
    trace.enable()
    try:
        traced = _checksums(_serve("interpreter", batch_decode=False,
                                   use_fused=False))
    finally:
        trace.disable()
    assert traced == ref


def test_tracing_does_not_perturb_pallas_serving(traced_serving):
    rep, _, _ = traced_serving
    assert _checksums(_serve("pallas")) == _checksums(rep)


# ---------------------------------------------------------------------------
# Chrome trace export: schema + per-request swimlanes
# ---------------------------------------------------------------------------

def test_chrome_trace_schema(tmp_path, traced_serving):
    _, events, _ = traced_serving
    path = export.write_chrome_trace(str(tmp_path / "trace.json"), events)
    doc = json.load(open(path))
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    assert evs, "traced serving produced no events"
    for rec in evs:
        assert rec["ph"] in ("X", "i", "M")
        if rec["ph"] == "M":
            assert rec["name"] in ("process_name", "thread_name")
            assert "name" in rec["args"]
        else:
            assert isinstance(rec["pid"], int)
            assert isinstance(rec["tid"], int)
            assert rec["ts"] >= 0
        if rec["ph"] == "X":
            assert rec["dur"] >= 0
        # args must be JSON-clean scalars/lists (Perfetto requirement)
        for v in rec.get("args", {}).values():
            assert isinstance(v, (str, int, float, bool, list)) or v is None


def test_chrome_trace_request_swimlanes(traced_serving):
    rep, events, _ = traced_serving
    doc = export.chrome_trace(events)
    evs = doc["traceEvents"]
    procs = {r["pid"]: r["args"]["name"] for r in evs
             if r["ph"] == "M" and r["name"] == "process_name"}
    assert "request" in procs.values() and "host" in procs.values()
    req_pid = next(p for p, n in procs.items() if n == "request")
    lanes = {r["tid"] for r in evs
             if r["ph"] == "M" and r["name"] == "thread_name"
             and r["pid"] == req_pid}
    assert len(lanes) == len(rep.requests)    # one swimlane per request
    # every request lane carries the full lifecycle
    by_name = {}
    for r in evs:
        if r["ph"] in ("X", "i") and r["pid"] == req_pid:
            by_name.setdefault(r["tid"], set()).add(r["name"])
    for lane_names in by_name.values():
        assert {"submit", "first_token", "retire",
                "decode_step", "request"} <= lane_names


def test_timeline_joins_spans_to_requests(traced_serving):
    rep, events, _ = traced_serving
    tl = rep.timeline(events)
    assert [t["rid"] for t in tl] == [r.rid for r in rep.requests]
    for entry, r in zip(tl, rep.requests):
        assert entry["state_checksum"] == r.state_checksum
        names = [s["name"] for s in entry["spans"]]
        assert "submit" in names and "retire" in names
        assert names.index("submit") < names.index("retire")
        assert any(n == "decode_step" for n in names)
        # spans are in time order
        t0s = [s["t0_s"] for s in entry["spans"]]
        assert t0s == sorted(t0s)
    # tracing off -> empty swimlanes, not an error
    assert all(t["spans"] == [] for t in rep.timeline([]))


def test_span_breakdown_decode_tick(traced_serving):
    rep, events, _ = traced_serving
    bd = export.span_breakdown("decode_tick", {"launch"}, events)
    assert bd["n_parents"] == rep.decode_ticks
    assert bd["n_children"] > 0
    assert 0.0 < bd["child_frac"] <= 1.0
    assert bd["child_frac"] + bd["host_frac"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Disabled-mode overhead: bounded against a measured decode tick
# ---------------------------------------------------------------------------

def test_disabled_overhead_under_two_percent_of_decode_tick(traced_serving):
    """events-per-tick x measured disabled per-call cost must stay under
    2% of the measured decode-tick wall clock (robust formulation: no
    differencing of two noisy end-to-end timings)."""
    rep, events, _ = traced_serving
    ticks = [e for e in events if e.name == "decode_tick"]
    assert ticks
    mean_tick_s = sum(e.dur_s for e in ticks) / len(ticks)
    # spans emitted inside one tick window, averaged
    per_tick = sum(
        1 for e in events
        if any(t.t0_s <= e.t0_s and e.t1_s <= t.t1_s + 1e-9
               for t in ticks)) / len(ticks)
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        with trace.span("x", a=1):
            pass
    per_call_s = (time.perf_counter() - t0) / n
    overhead = per_tick * per_call_s
    assert overhead < 0.02 * mean_tick_s, (
        f"disabled tracing overhead {overhead * 1e6:.1f}us/tick vs "
        f"tick {mean_tick_s * 1e6:.1f}us ({per_tick:.0f} spans/tick at "
        f"{per_call_s * 1e9:.0f}ns/span)")


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_and_gauge_labels():
    reg = Registry()
    c = reg.counter("events_total", "help text")
    c.inc(1, tier="plan", kind="hit")
    c.inc(2, tier="plan", kind="hit")
    c.inc(5, tier="plan", kind="miss")
    c.inc(7)
    assert c.value(tier="plan", kind="hit") == 3
    assert c.value(tier="plan", kind="miss") == 5
    assert c.value() == 7
    g = reg.gauge("depth")
    g.set(4, pool="kv")
    g.set(2, pool="kv")
    assert g.value(pool="kv") == 2
    g.high(9, pool="kv")
    g.high(3, pool="kv")
    assert g.value(pool="kv") == 9


def test_registry_kind_mismatch_raises():
    reg = Registry()
    reg.counter("x_total")
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x_total")


def test_set_many_skips_non_numeric():
    reg = Registry()
    reg.set_many({"a": 1, "b": 2.5, "flag": True, "name": "str",
                  "lst": [1, 2]}, prefix="p_")
    snap = reg.snapshot()
    assert snap["p_a"][""] == 1.0 and snap["p_b"][""] == 2.5
    assert "p_flag" not in snap and "p_name" not in snap
    assert "p_lst" not in snap


def test_prometheus_rendering_deterministic():
    reg = Registry()
    reg.counter("b_total", "bees").inc(2, kind="x")
    reg.gauge("a_gauge").set(1.5)
    text = reg.render_prometheus()
    assert text == reg.render_prometheus()
    lines = text.strip().splitlines()
    assert lines[0] == "# TYPE a_gauge gauge"
    assert "a_gauge 1.5" in lines
    assert "# HELP b_total bees" in lines
    assert "# TYPE b_total counter" in lines
    assert 'b_total{kind="x"} 2' in lines


def test_reset_keeps_registered_handles():
    reg = Registry()
    handle = reg.counter("launches_total")
    handle.inc(3)
    reg.reset()
    assert handle.value() == 0
    handle.inc(1)    # module-level handles must stay attached
    assert reg.snapshot()["launches_total"][""] == 1.0


# ---------------------------------------------------------------------------
# Scheduler -> registry bridge + report surfaces
# ---------------------------------------------------------------------------

def test_serving_publishes_unified_metrics(traced_serving):
    rep, _, snap = traced_serving
    # MINISA vs micro instruction byte counters, labelled by backend
    assert snap["minisa_bytes_total"]['{backend="pallas"}'] == \
        pytest.approx(sum(r.minisa_bytes for r in rep.requests))
    assert snap["micro_bytes_total"]['{backend="pallas"}'] == \
        pytest.approx(sum(r.micro_bytes for r in rep.requests))
    # per-kernel launch counter sums to the scheduler's launch count
    assert sum(snap["backend_launches_total"].values()) >= \
        rep.decode_launches
    # cache tiers (disk stats included) and KV pool stats
    assert snap["cache_hits"]['{tier="plan"}'] >= 0
    assert "cache_disk_bytes" in snap and "cache_disk_evictions" in snap
    assert snap["kv_high_water_pages"][""] == \
        rep.kv["high_water_pages"]
    assert snap["kv_admit_stalls"][""] == rep.kv["admit_stalls"]
    # scheduler summary gauges
    assert snap["sched_tokens_per_sec"][""] > 0
    assert snap["sched_latency_p99_s"][""] > 0


def test_report_to_dict_carries_cache_disk_and_kv(traced_serving):
    rep, _, _ = traced_serving
    d = rep.to_dict()
    assert len(d["requests"]) == len(rep.requests)
    assert "disk_bytes" in d["cache"] and "disk_evictions" in d["cache"]
    assert "admit_stalls" in d["kv"] and "high_water_pages" in d["kv"]
    assert d["latency_p99_s"] == rep.summary()["latency_p99_s"]


def test_latency_and_ttft_percentile_sets(traced_serving):
    """The report carries the full p50/p95/p99 set for both end-to-end
    latency and TTFT, ordered and bounded by the observed walls."""
    rep, _, _ = traced_serving
    s = rep.summary()
    walls = [r.wall_s for r in rep.requests]
    ttfts = [r.ttft_s for r in rep.requests]
    for prefix, vals in (("latency", walls), ("ttft", ttfts)):
        p50, p95, p99 = (s[f"{prefix}_p50_s"], s[f"{prefix}_p95_s"],
                         s[f"{prefix}_p99_s"])
        assert 0.0 < p50 <= p95 <= p99
        assert p99 <= max(vals) + 1e-9
        assert min(vals) - 1e-9 <= p50
