"""Substrate tests: checkpointing (atomic/async/elastic), optimizer,
data pipeline determinism, sharding rules, gradient compression, serving
engine end-to-end."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.ckpt.manager import CheckpointManager
from repro.configs.base import ShapeConfig, reduced
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist import sharding as shlib
from repro.dist.compression import fake_quantize_int8
from repro.models import build_model
from repro.serve.engine import Engine, ServeConfig
from repro.train import optimizer as optlib


# ---------------------------------------------------------------------------
# Checkpoint manager
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.float32),
                  "d": [jnp.zeros((2, 2)), jnp.full((3,), 7.0)]}}


def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(10, tree)
    assert mgr.latest_step() == 10
    restored = mgr.restore(10, jax.eval_shape(lambda: tree))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b),
                 tree, restored)


def test_ckpt_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, {"x": jnp.full((4,), float(s))})
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    r = mgr.restore(4, jax.eval_shape(lambda: {"x": jnp.zeros((4,))}))
    assert float(r["x"][0]) == 4.0


def test_ckpt_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore(1, jax.eval_shape(lambda: {"x": jnp.zeros((5,))}))


def test_ckpt_atomicity_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _tree())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = optlib.OptimizerConfig(peak_lr=0.1, warmup_steps=5,
                                 total_steps=200, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = optlib.init(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 1.0))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = optlib.update(cfg, params, g, state)
    assert float(loss(params)) < 1e-2


def test_adamw_master_weights_decouple_dtype():
    cfg = optlib.OptimizerConfig(peak_lr=1e-2, warmup_steps=1,
                                 total_steps=10)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = optlib.init(params)
    g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    params, state, _ = optlib.update(cfg, params, g, state)
    assert params["w"].dtype == jnp.bfloat16
    assert state["master"]["w"].dtype == jnp.float32


def test_grad_clip():
    tree = {"a": jnp.full((100,), 10.0)}
    clipped, norm = optlib.clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(100.0)
    assert float(optlib.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_per_step():
    cfg = reduced(get_config("gemma-7b"))
    shape = ShapeConfig("t", 64, 4, "train")
    d1 = SyntheticLM(DataConfig(seed=1), cfg, shape)
    d2 = SyntheticLM(DataConfig(seed=1), cfg, shape)
    np.testing.assert_array_equal(d1.batch(17)["tokens"],
                                  d2.batch(17)["tokens"])
    assert not np.array_equal(d1.batch(17)["tokens"],
                              d1.batch(18)["tokens"])


# ---------------------------------------------------------------------------
# Sharding rules (AbstractMesh: no devices needed)
# ---------------------------------------------------------------------------

MESH = shlib.abstract_mesh((16, 16), ("data", "model"))
POD_MESH = shlib.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_spec_tp_and_fsdp():
    # (embed, ffn): FSDP on data + TP on model
    assert shlib.spec_for(("embed", "ffn"), (8192, 29568), MESH) == \
        P("data", "model")
    # vocab embedding
    assert shlib.spec_for(("vocab", "embed"), (152064, 8192), MESH) == \
        P("model", "data")


def test_spec_kv_heads_fallback_to_seq():
    # qwen2: 8 kv heads % 16 != 0 -> heads replicated, kvseq sharded
    spec = shlib.spec_for(("layers", "batch", "kvseq", "kv_heads",
                           "head_dim"), (80, 128, 32768, 8, 128), MESH)
    assert spec == P(None, "data", "model")


def test_spec_experts_divisibility():
    # TP-inside-expert policy (§Perf iteration 6b): experts stay unsharded
    # and each expert's ffn dim is TP-sharded -- per-device weight bytes
    # match EP when both divide, and the dispatch/combine stays row-local.
    assert shlib.spec_for(("experts", "embed", "expert_ffn"),
                          (160, 5120, 1536), MESH) == \
        P(None, "data", "model")
    assert shlib.spec_for(("experts", "embed", "expert_ffn"),
                          (40, 1536, 512), MESH) == \
        P(None, "data", "model")


def test_inference_rules_drop_fsdp():
    # serving replicates weights over data (no FSDP gather-at-use)
    assert shlib.spec_for(("embed", "ffn"), (8192, 29568), MESH,
                          shlib.INFERENCE_RULES) == P(None, "model")
    # TP/SP unchanged
    assert shlib.spec_for(("layers", "batch", "kvseq", "kv_heads",
                           "head_dim"), (80, 128, 32768, 8, 128),
                          MESH, shlib.INFERENCE_RULES) == \
        P(None, "data", "model")


def test_spec_pod_axis_batch():
    spec = shlib.spec_for(("batch", "embed"), (512, 1024), POD_MESH)
    assert spec == P(("pod", "data"), None) or spec == P(("pod", "data"))


def test_no_axis_used_twice():
    spec = shlib.spec_for(("ffn", "ssm_inner"), (4096, 4096), MESH)
    flat = [s for s in spec if s is not None]
    assert len(set(flat)) == len(flat)


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------

def test_int8_fake_quant_error_bounded():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    q = fake_quantize_int8(x)
    err = jnp.abs(q - x).max()
    assert float(err) <= float(jnp.abs(x).max()) / 127.0 + 1e-6


# ---------------------------------------------------------------------------
# Serving engine end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["minitron-4b", "falcon-mamba-7b"])
def test_engine_generates(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(max_len=24))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    toks = engine.generate(prompts, steps=6)
    assert toks.shape == (2, 6)
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()


def test_engine_greedy_matches_rerun():
    """Greedy decode is deterministic."""
    cfg = reduced(get_config("minitron-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(max_len=24))
    prompts = np.full((1, 8), 3, np.int32)
    a = engine.generate(prompts, steps=5)
    b = engine.generate(prompts, steps=5)
    np.testing.assert_array_equal(a, b)
