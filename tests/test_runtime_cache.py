"""ProgramCache: hit/miss accounting, structural key stability, disk
round-trip, and cached-Plan execution equivalence."""

import dataclasses

import numpy as np
import pytest

from repro.configs.feather import feather_config
from repro.core import mapper, program
from repro.runtime.cache import ProgramCache, compiled_key

CFG = feather_config(4, 16)
G = mapper.Gemm(m=24, k=20, n=16, name="cache-gemm")


def _tensors(g, seed=0):
    rng = np.random.default_rng(seed)
    return {"I": rng.standard_normal((g.m, g.k)).astype(np.float32),
            "W": rng.standard_normal((g.k, g.n)).astype(np.float32)}


def test_plan_hit_miss_accounting():
    cache = ProgramCache()
    p1 = cache.plan(G, CFG)
    assert (cache.stats.plan_misses, cache.stats.plan_hits) == (1, 0)
    assert cache.stats.searches == 1
    p2 = cache.plan(G, CFG)
    assert p2 is p1
    assert (cache.stats.plan_misses, cache.stats.plan_hits) == (1, 1)
    assert cache.stats.hit_rate == 0.5
    assert len(cache) == 1
    assert cache.size_bytes() > 0


def test_key_stable_across_equal_instances():
    """Equal-by-value Gemm/FeatherConfig objects share one entry; name and
    count are metadata, not part of the mapping-search problem."""
    cache = ProgramCache()
    cache.plan(G, CFG)
    other_gemm = mapper.Gemm(m=G.m, k=G.k, n=G.n, name="other", count=7)
    other_cfg = feather_config(4, 16)   # fresh but equal instance
    assert other_cfg is not CFG and other_cfg == CFG
    cache.plan(other_gemm, other_cfg)
    assert cache.stats.searches == 1 and cache.stats.plan_hits == 1
    # different search kwargs are a different problem
    cache.plan(G, CFG, fixed_input_vn=4)
    assert cache.stats.searches == 2


def test_cached_plan_executes_identically(tmp_path):
    """A cache-served Plan (memory hit and disk round-trip) produces
    bit-identical outputs to a freshly searched one."""
    path = tmp_path / "plans.pkl"
    cache = ProgramCache(path=path)
    plan = cache.plan(G, CFG)
    t = _tensors(G)
    fresh = mapper.search(G, CFG).execute(t)["O"]
    np.testing.assert_array_equal(plan.execute(t)["O"], fresh)
    cache.save()

    reloaded = ProgramCache(path=path)
    assert reloaded.stats.loaded_from_disk == 1
    plan2 = reloaded.plan(G, CFG)
    assert reloaded.stats.searches == 0 and reloaded.stats.plan_hits == 1
    np.testing.assert_array_equal(plan2.execute(t)["O"], fresh)
    np.testing.assert_array_equal(plan2.execute(t, backend="pallas")["O"],
                                  plan.execute(t, backend="pallas")["O"])


def test_disk_version_guard(tmp_path):
    import pickle
    path = tmp_path / "bad.pkl"
    with open(path, "wb") as f:
        pickle.dump({"version": -1, "plans": {}}, f)
    with pytest.raises(ValueError, match="version"):
        ProgramCache(path=path)


def test_lower_tier_memoises_variants():
    cache = ProgramCache()
    plan = cache.plan(G, CFG)
    a = cache.lower(plan.gemm, plan.choice, CFG, out_name="O0")
    b = cache.lower(plan.gemm, plan.choice, CFG, out_name="O0")
    c = cache.lower(plan.gemm, plan.choice, CFG, out_name="O1")
    assert a is b and a is not c
    assert cache.stats.lowered_misses == 2
    assert cache.stats.lowered_hits == 1


def test_lru_eviction_bounds_plan_tier():
    cache = ProgramCache(max_plans=2)
    for n in (8, 12, 16):
        cache.plan(mapper.Gemm(m=8, k=8, n=n), CFG)
    assert cache.stats.evictions == 1
    # evicted entry (n=8, oldest) re-searches; resident ones hit
    cache.plan(mapper.Gemm(m=8, k=8, n=16), CFG)
    assert cache.stats.plan_hits == 1
    cache.plan(mapper.Gemm(m=8, k=8, n=8), CFG)
    assert cache.stats.searches == 4


def test_compiled_tier_structural_key():
    """Two equivalent-but-distinct Program objects share one compiled
    artifact; the PallasBackend hook routes through the shared tier."""
    from repro import backends

    cache = ProgramCache()
    plan = cache.plan(G, CFG)
    p1 = program.lower(G, plan.choice, CFG, out_name="O")
    p2 = program.lower(G, plan.choice, CFG, out_name="O")
    assert p1 is not p2
    assert compiled_key(p1, 2048) == compiled_key(p2, 2048)

    be1 = backends.PallasBackend(CFG, compile_cache=cache)
    be2 = backends.PallasBackend(CFG, compile_cache=cache)
    comp1 = be1.compile(p1)
    comp2 = be2.compile(p2)   # fresh object, fresh backend: shared hit
    assert comp2 is comp1
    assert be1.n_compiles == 1 and be2.n_compiles == 0
    assert cache.stats.compile_misses == 1
    assert cache.stats.compile_hits == 1
    # numbers are unaffected by cache routing
    t = _tensors(G)
    out = be2.run_program(p2, t)["O"]
    np.testing.assert_allclose(out, t["I"] @ t["W"], rtol=2e-4,
                               atol=2e-4 + 2e-4 * G.k)


def test_stats_snapshot_delta():
    cache = ProgramCache()
    cache.plan(G, CFG)
    snap = cache.stats.snapshot()
    cache.plan(G, CFG)
    cache.plan(dataclasses.replace(G, n=G.n * 2), CFG)
    d = cache.stats.delta(snap)
    assert d["plan_hits"] == 1 and d["plan_misses"] == 1
